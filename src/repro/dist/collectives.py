"""Collective-communication helpers (weighted all-reduce, int8 EF compression).

SPARe's failure masking is, at the wire level, nothing but a *weighted*
gradient all-reduce: every (group, stack-slot) contributes its partial
gradient scaled by the supplier weight (``1/N`` for the designated
supplier of a shard type, ``0`` otherwise — :meth:`repro.core.SpareState
.device_schedule`), so the collected gradient equals vanilla DP's batch
gradient for every survivor set (§3.1 invariant). This module is the one
place that reduction is issued:

* on a real mesh (inside ``pmap``/``shard_map``) pass ``axis_name`` and
  the helper lowers to a single ``psum`` — failure masking costs zero
  extra collectives;
* host-side (laptop-scale emulation, trainers, tests) the same call is a
  plain weighted contraction with identical numerics.

The int8 error-feedback compressor is a beyond-paper bandwidth
optimization for the 20 TB-gradient all-reduce (paper Table 1): gradients
are quantized to int8 with a per-tensor scale (4x traffic reduction) and
the quantization residual is fed back into the next step's compression,
making the *cumulative* transmitted signal unbiased (Seide et al. 2014;
Karimireddy et al. 2019 — EF-SGD).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["weighted_all_reduce", "psum_partial", "all_reduce_grads",
           "constrain_grad", "compress_grad_int8", "decompress_grad_int8",
           "BucketLayout", "bucket_layout", "flatten_grads",
           "unflatten_grads", "BucketedAllReduce", "CompressedBucketSync",
           "shard_map_compat"]

try:  # moved to jax.shard_map in newer releases
    from jax.experimental.shard_map import shard_map as _shard_map_raw
except ImportError:  # pragma: no cover - future jax
    _shard_map_raw = jax.shard_map


def shard_map_compat(fn, *, mesh, in_specs, out_specs):
    """``shard_map`` across jax versions, replication checking off.

    Two renames straddle the pinned toolchain: the function moved from
    ``jax.experimental.shard_map`` to ``jax.shard_map``, and the
    replication-checker flag from ``check_rep`` to ``check_vma``. Every
    manual program in the repo (the mesh executor's step, the MoE
    expert-parallel ffn) declares replicated out_specs the checker
    cannot prove through psum/custom_vjp, so it is disabled under
    whichever name exists.
    """
    try:
        return _shard_map_raw(fn, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)
    except TypeError:  # pragma: no cover - newer jax
        return _shard_map_raw(fn, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def psum_partial(x: jax.Array, axis_name) -> jax.Array:
    """``psum`` whose inputs are *partial sums*, with the matching VJP.

    Inside ``shard_map`` each device holds its own partial contribution
    (a local weighted gradient, a local weighted loss): the derivative of
    the global sum w.r.t. a device's partial is exactly 1, so the
    backward pass is the identity. The stock ``lax.psum`` cannot know
    this — under ``check_rep=False`` its transpose is another ``psum``,
    which silently multiplies every gradient by the axis size (we
    measured exactly ``dp_degree``x on the first mesh bring-up). Routing
    the §3.1 reduction through this wrapper is what lets
    ``value_and_grad`` of a psummed loss return the correct *local*
    partial gradient, which is then all-reduced once per step.
    """
    return jax.lax.psum(x, axis_name)


def _psum_partial_fwd(x, axis_name):
    return jax.lax.psum(x, axis_name), None


def _psum_partial_bwd(axis_name, _res, ct):
    return (ct,)


psum_partial.defvjp(_psum_partial_fwd, _psum_partial_bwd)


def weighted_all_reduce(values: jax.Array, weights: jax.Array,
                        axis_name: str | None = None) -> jax.Array:
    """Supplier-weighted reduction ``Σ_i weights_i · values_i``.

    ``values`` and ``weights`` share a leading contraction shape (the
    per-example / per-slot axis); the result is the scalar (or trailing-
    shape) weighted sum. With ``axis_name`` set, the local partial sum is
    additionally ``psum``-reduced across the named mapped axis — this is
    the production spelling of the §3.1 weighted all-reduce; without it,
    the call is the exact host-side emulation. The psum is the
    partial-sum flavor (:func:`psum_partial`), so differentiating a loss
    built on this reduction yields each device's own partial gradient —
    see :func:`all_reduce_grads` for the per-step gradient sync.
    """
    w = weights.reshape(weights.shape + (1,) * (values.ndim - weights.ndim))
    local = jnp.sum(values * w.astype(values.dtype),
                    axis=tuple(range(weights.ndim)))
    if axis_name is not None:
        local = psum_partial(local, axis_name)
    return local


def all_reduce_grads(grads, axis_name: str):
    """One gradient all-reduce per step: psum every leaf of the (already
    supplier-weighted) local gradient pytree across the mapped data axis.

    This is the single collective SPARe's failure masking rides on — the
    weights folded into the per-example loss make the psummed result
    equal vanilla DP's batch gradient for every survivor set, so masking
    a failure never changes the collective schedule (paper §3.1, "zero
    extra collectives").
    """
    return jax.tree.map(lambda g: psum_partial(g, axis_name), grads)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def constrain_grad(x: jax.Array, sharding) -> jax.Array:
    """Identity forward; pins the *cotangent* to ``sharding``.

    Used to force GSPMD to reduce-scatter weight gradients to their
    shard at the point of production (inside the backward of the layer
    scan) instead of all-reducing them to replicated form inside the
    loop.
    """
    return x


def _constrain_grad_fwd(x, sharding):
    return x, None


def _constrain_grad_bwd(sharding, _res, ct):
    return (jax.lax.with_sharding_constraint(ct, sharding),)


constrain_grad.defvjp(_constrain_grad_fwd, _constrain_grad_bwd)


def compress_grad_int8(
    grad: jax.Array, error: jax.Array, *, fused: bool | None = None
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Int8 error-feedback quantization of one gradient tensor.

    Compresses ``grad + error`` (the fresh gradient plus the residual the
    previous step failed to transmit) to int8 with a shared per-tensor
    scale, and returns the residual to carry into the next step::

        q, scale, new_error = compress_grad_int8(grad, error)
        wire_bytes = q          # 1/4 of fp32
        restored   = decompress_grad_int8(q, scale)
        # invariant: restored + new_error == grad + error   (exactly)

    Returns ``(q, scale, new_error)`` where ``q`` is int8 with the same
    shape as ``grad``, ``scale`` is the scalar dequantization step, and
    ``new_error = (grad + error) - decompress(q, scale)``.

    The whole arithmetic runs in fp32 regardless of ``grad``'s dtype:
    :func:`decompress_grad_int8` dequantizes in fp32, so a residual
    computed in e.g. bf16 would break the exact invariant above (the
    bf16 rounding of ``x - q*scale`` diverges from the fp32 value the
    receiver reconstructs). ``error`` carries the fp32 residual between
    steps; ``new_error`` is always returned as fp32.

    The max quantization error of a single step is ``scale/2 <= scale``;
    with error feedback the *cumulative* transmitted signal converges to
    the cumulative true gradient, which is what makes aggressive 8-bit
    compression safe for SGD-family optimizers.

    ``fused`` routes through the Pallas quantize-accumulate kernel
    (:func:`repro.kernels.ops.int8_ef_quantize`): one VMEM pass computes
    the EF accumulate, the quantization, and the residual together
    instead of the unfused XLA chain. Defaults to the kernel on TPU and
    the plain jnp spelling elsewhere; both compute the identical fp32
    math — ``q`` and ``scale`` bit-identical, the residual up to one
    fp32 ulp (compiler FMA contraction of ``x - q*scale``; the exact
    invariant above strictly holds on the op-by-op/eager path).
    """
    if fused is None:
        from repro.kernels.ops import on_tpu
        fused = on_tpu()
    if fused:
        from repro.kernels.ops import int8_ef_quantize
        return int8_ef_quantize(grad, error)
    # the unfused spelling IS the kernel oracle — one definition of the
    # accumulate/scale/clip/residual math keeps the bit-identical
    # contract between the paths from drifting
    from repro.kernels.ref import int8_ef_ref
    return int8_ef_ref(grad, error)


def decompress_grad_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`compress_grad_int8`: ``q * scale`` in fp32."""
    return q.astype(jnp.float32) * scale


# --------------------------------------------------------------------- #
# bucketed flat gradient sync                                           #
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class BucketLayout:
    """Deterministic flat-bucket layout of a gradient pytree.

    Leaves (in ``jax.tree`` order) are packed first-fit-in-order into
    contiguous fp32 buckets capped at ``max_bucket_elems`` (a leaf larger
    than the cap gets a bucket of its own), and every bucket is
    zero-padded up to a multiple of ``pad_to`` (the data-parallel chunk
    granularity of the compressed sync). The layout is a pure function of
    (tree structure, leaf shapes, cap, pad) — compress and decompress
    sides derive byte-identical placement with no coordination.
    """

    treedef: object
    shapes: tuple[tuple[int, ...], ...]    # per leaf
    dtypes: tuple[str, ...]                # per leaf (original dtype name)
    bucket_of: tuple[int, ...]             # leaf -> bucket index
    offsets: tuple[int, ...]               # leaf -> element offset in bucket
    bucket_sizes: tuple[int, ...]          # padded element counts
    pad_to: int

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_sizes)

    @property
    def n_elems(self) -> int:
        return sum(self.bucket_sizes)


def bucket_layout(tree, *, max_bucket_elems: int = 1 << 23,
                  pad_to: int = 1) -> BucketLayout:
    """Pack ``tree``'s leaves (arrays or ShapeDtypeStructs) into buckets."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes, dtypes, bucket_of, offsets = [], [], [], []
    sizes: list[int] = []          # unpadded fill of each open bucket
    for leaf in leaves:
        n = int(np.prod(leaf.shape, dtype=np.int64)) if leaf.shape else 1
        shapes.append(tuple(leaf.shape))
        dtypes.append(jnp.dtype(leaf.dtype).name)
        if not sizes or sizes[-1] + n > max_bucket_elems and sizes[-1] > 0:
            sizes.append(0)
        bucket_of.append(len(sizes) - 1)
        offsets.append(sizes[-1])
        sizes[-1] += n
    padded = tuple(-(-s // pad_to) * pad_to for s in sizes)
    return BucketLayout(treedef=treedef, shapes=tuple(shapes),
                        dtypes=tuple(dtypes), bucket_of=tuple(bucket_of),
                        offsets=tuple(offsets), bucket_sizes=padded,
                        pad_to=pad_to)


def flatten_grads(layout: BucketLayout, tree) -> list[jax.Array]:
    """Pytree -> list of contiguous fp32 1-D buckets (zero-padded)."""
    leaves = layout.treedef.flatten_up_to(tree)
    parts: list[list[jax.Array]] = [[] for _ in layout.bucket_sizes]
    fill = [0] * layout.n_buckets
    for i, leaf in enumerate(leaves):
        b = layout.bucket_of[i]
        parts[b].append(leaf.astype(jnp.float32).reshape(-1))
        fill[b] += parts[b][-1].size
    bufs = []
    for b, chunks in enumerate(parts):
        buf = jnp.concatenate(chunks) if len(chunks) > 1 else chunks[0]
        pad = layout.bucket_sizes[b] - fill[b]
        if pad:
            buf = jnp.pad(buf, (0, pad))
        bufs.append(buf)
    return bufs


def unflatten_grads(layout: BucketLayout, bufs) -> object:
    """Inverse of :func:`flatten_grads`; bit-transparent round trip.

    fp32 leaves come back untouched; bf16/fp16 leaves round-trip exactly
    because widening to fp32 is lossless and the cast back merely undoes
    it (the uncompressed bucketed psum adds device partials in fp32 — the
    same element order and width the per-leaf psum used).
    """
    leaves = []
    for i, shape in enumerate(layout.shapes):
        b, off = layout.bucket_of[i], layout.offsets[i]
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        leaf = jax.lax.slice(bufs[b], (off,), (off + n,)).reshape(shape)
        leaves.append(leaf.astype(layout.dtypes[i]))
    return jax.tree.unflatten(layout.treedef, leaves)


class BucketedAllReduce:
    """O(1)-collective gradient sync: psum a handful of flat buckets.

    Replaces the one-``psum``-per-parameter-leaf spelling of
    :func:`all_reduce_grads` inside ``shard_map``: the gradient pytree is
    flattened through a :class:`BucketLayout` (a fixed, small number of
    size-capped fp32 buffers), each bucket is psummed once, and the tree
    is rebuilt bit-transparently. Collective count per step is
    ``layout.n_buckets`` regardless of how many hundred leaves the model
    has; numerics are element-for-element identical to the per-leaf psum
    (same adds, same order, same fp32 width).
    """

    stateful = False

    def __init__(self, layout: BucketLayout, axis_name: str):
        self.layout = layout
        self.axis_name = axis_name

    def __call__(self, grads):
        bufs = flatten_grads(self.layout, grads)
        bufs = [psum_partial(b, self.axis_name) for b in bufs]
        return unflatten_grads(self.layout, bufs)


class CompressedBucketSync:
    """Two-phase int8 error-feedback all-reduce over flat buckets.

    The wire protocol (per bucket of ``B`` fp32 elements, data-parallel
    degree ``dp``), all arithmetic fp32 — int8 payloads are *gathered*
    and dequant-accumulated, never int-psummed, so there is no overflow
    at any ``dp``:

    1. quantize the local partial bucket (+ stage-1 EF residual) to int8
       with one fp32 scale per (device, bucket);
    2. ``all_to_all`` the int8 payload: device ``i`` receives every
       device's quantized partial of chunk ``i`` (``B`` int8 wire bytes),
       plus an ``all_gather`` of the ``dp`` fp32 scales;
    3. dequant-accumulate the chunk in fp32 — device ``i`` now owns the
       exact (up to stage-1 quantization) reduced chunk ``i``;
    4. re-quantize the reduced chunk (+ stage-2 EF residual, owned by
       the same device every step) and ``all_gather`` int8 chunks +
       scales back to everyone (``B`` int8 wire bytes);
    5. dequantize locally into the full reduced bucket.

    Wire bytes ~= ``2B`` vs the fp32 ring all-reduce's ``8B`` — the ~4x
    reduction gated by ``launch/hlo.py`` — and the collective *count* is
    a constant 4 per bucket, independent of the survivor set (masking
    stays weight data; the schedule is byte-identical masked vs
    unmasked). Both EF residuals are device-local sharded state
    (flat arrays split over the data axis) threaded through the train
    step; the cumulative transmitted gradient stays unbiased through
    both quantizations (Seide et al. 2014; Tang et al. 2019 — the
    1-bit-Adam-style two-stage EF).
    """

    stateful = True

    #: deep-mode telemetry (a ``repro.obs.Telemetry``), attached post-hoc
    #: by the mesh executor: emits in-jit ``bucket/<i>`` markers around
    #: each bucket's wire phases via ``jax.debug.callback``. Changing it
    #: changes the traced program — strictly an attribution-session knob.
    tel = None

    def __init__(self, layout: BucketLayout, dp_degree: int,
                 axis_name: str, *, fused: bool | None = None):
        for b, size in enumerate(layout.bucket_sizes):
            if size % dp_degree:
                raise ValueError(
                    f"bucket {b} has {size} elements, not divisible by "
                    f"dp_degree={dp_degree}; build the layout with "
                    f"pad_to={dp_degree} (or a multiple)")
        self.layout = layout
        self.dp = dp_degree
        self.axis_name = axis_name
        self.fused = fused

    # -- EF state plumbing (global view, host side) ------------------- #
    def init_state(self):
        """Zero EF residuals, *global* shapes: ``err1[b]`` is every
        device's stage-1 residual for bucket ``b`` laid out flat
        (``dp * B`` fp32, device-sharded), ``err2[b]`` the chunk-owner
        stage-2 residual (``B`` fp32, device-sharded)."""
        return {
            "err1": tuple(np.zeros(self.dp * s, np.float32)
                          for s in self.layout.bucket_sizes),
            "err2": tuple(np.zeros(s, np.float32)
                          for s in self.layout.bucket_sizes),
        }

    def state_specs(self):
        """PartitionSpecs matching :meth:`init_state` (both residual
        families shard flat over the data axis — pure device-local
        state, no cross-device meaning)."""
        from jax.sharding import PartitionSpec as P
        spec = P(self.axis_name)
        return {"err1": tuple(spec for _ in self.layout.bucket_sizes),
                "err2": tuple(spec for _ in self.layout.bucket_sizes)}

    # -- the sync itself (device side, inside shard_map) -------------- #
    def _sync_bucket(self, buf, e1, e2):
        q1, s1, e1_new = compress_grad_int8(buf, e1, fused=self.fused)
        # ship everyone's partial of my chunk; scales ride separately
        mine = jax.lax.all_to_all(q1.reshape(self.dp, -1),
                                  self.axis_name, 0, 0)       # (dp, B/dp)
        scales = jax.lax.all_gather(s1, self.axis_name)       # (dp,)
        chunk = jnp.einsum("j,jk->k", scales,
                           mine.astype(jnp.float32))          # fp32 sum
        q2, s2, e2_new = compress_grad_int8(chunk, e2, fused=self.fused)
        full_q = jax.lax.all_gather(q2, self.axis_name)       # (dp, B/dp)
        full_s = jax.lax.all_gather(s2, self.axis_name)       # (dp,)
        out = (full_q.astype(jnp.float32) * full_s[:, None]).reshape(-1)
        return out, e1_new, e2_new

    def __call__(self, grads, state):
        """Local (per-device) view: ``state['err1'][b]`` is this
        device's full-bucket residual, ``state['err2'][b]`` its owned
        chunk's. Returns (reduced grads pytree, new state)."""
        bufs = flatten_grads(self.layout, grads)
        out, ne1, ne2 = [], [], []
        tel = self.tel
        if tel is not None:
            tel.jit_instant("grad_sync", "sync", bufs[0])
        for b, (buf, e1, e2) in enumerate(zip(bufs, state["err1"],
                                              state["err2"])):
            if tel is not None:
                tel.jit_instant(f"bucket/{b}", "sync", buf)
            full, e1n, e2n = self._sync_bucket(buf, e1, e2)
            if tel is not None:
                tel.jit_instant(f"bucket/{b}/done", "sync", full)
            out.append(full)
            ne1.append(e1n)
            ne2.append(e2n)
        return (unflatten_grads(self.layout, out),
                {"err1": tuple(ne1), "err2": tuple(ne2)})

    def sync_once(self, grads):
        """Stateless spelling (zero residuals) for verification paths —
        single-step quantization error only, bounded by the §3.1
        quantization-tolerance oracle in ``exec/equivalence.py``."""
        zeros = {
            "err1": tuple(jnp.zeros(s, jnp.float32)
                          for s in self.layout.bucket_sizes),
            "err2": tuple(jnp.zeros(s // self.dp, jnp.float32)
                          for s in self.layout.bucket_sizes),
        }
        reduced, _ = self(grads, zeros)
        return reduced
