"""Name-based production sharding rules (FSDP x TP on the launch meshes).

One rule table maps every parameter leaf — identified by its dict key and
rank — to a :class:`~jax.sharding.PartitionSpec` over the production mesh
axes from :mod:`repro.launch.mesh` (``(pod,) data, model``):

* **column-parallel** projections (``wq``/``wk``/``wv``, MLP up/gate,
  MLA down-projections): output features on ``model``, input features
  FSDP-sharded across the data axes;
* **row-parallel** projections (``wo``, MLP down): input features on
  ``model``, output features FSDP across data;
* **routed experts** (3-D ``w_gate``/``w_up``/``w_down``): expert axis on
  ``model`` — the EP layout :func:`repro.models.moe.moe_ffn` expects;
* **vectors** (norm scales, biases, ``a_log``...) and the tiny router:
  replicated.

The same ``_rule`` feeds two consumers: :func:`param_specs` (the jit
in/out shardings the dry-run and the mesh executor place parameters
with) and ``Model._pin_layer_grads`` (per-leaf *gradient* constraints
via :func:`repro.dist.collectives.constrain_grad`, issued inside the
layer scan so GSPMD reduce-scatters weight grads to their shard instead
of all-reducing them replicated).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["param_specs", "opt_specs", "batch_spec", "cache_specs",
           "paged_cache_specs", "mesh_axis_sizes"]

# output features live on the model axis; input features are FSDP
_COL_PARALLEL = {"wq", "wk", "wv", "w_in", "w_gate", "w_up",
                 "wq_a", "wq_b", "wkv_a", "wk_b", "wv_b"}
# input features live on the model axis; output features are FSDP
_ROW_PARALLEL = {"wo", "w_down", "w_out"}
# small / irregular leaves that stay replicated everywhere
_REPLICATED = {"router", "conv_w", "conv_b", "dt_bias", "a_log",
               "kv_norm", "q_norm", "ln1", "ln2", "final_norm"}


def _rule(name: str | None, ndim: int, dp_axes: tuple[str, ...]):
    """Spec entries (len ``ndim``) for one *unstacked* parameter leaf."""
    dp = tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0]
    if ndim < 2 or name in _REPLICATED or name is None:
        return (None,) * ndim
    if name == "embed":          # token table: vocab FSDP, features TP
        return (dp, "model")
    if name == "lm_head":        # logits want vocab on model
        return (dp, "model")
    if name in _COL_PARALLEL:
        if ndim == 3:            # routed experts (E, d_in, d_out): EP
            return ("model", None, None)
        return (None,) * (ndim - 2) + (dp, "model")
    if name in _ROW_PARALLEL:
        if ndim == 3:
            return ("model", None, None)
        return (None,) * (ndim - 2) + ("model", dp)
    return (None,) * ndim        # unknown leaf: stay safe, replicate


def mesh_axis_sizes(mesh) -> dict[str, int]:
    """``{axis name: size}`` for any mesh — the ``axis_sizes`` argument
    :func:`param_specs` takes to fit one rule table to that mesh."""
    return {name: int(size) for name, size in dict(mesh.shape).items()}


def _fit(entries, shape, axis_sizes):
    """Drop spec entries a concrete mesh cannot honor: when every axis
    of an entry has a known size and the dimension does not divide their
    product, that dimension falls back to replicated. Entries naming any
    unknown axis pass through untouched (the caller's mesh may still
    honor them), so ``axis_sizes=None`` is the identity — one rule table
    serves the original mesh and every elastic survivor submesh."""
    if axis_sizes is None:
        return entries
    out = []
    for dim, e in zip(shape, entries):
        if e is None:
            out.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        sizes = [axis_sizes.get(a) for a in axes]
        if all(s is not None for s in sizes) and \
                int(dim) % math.prod(int(s) for s in sizes):
            out.append(None)
        else:
            out.append(e)
    return tuple(out)


def _leaf_name(path) -> str | None:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return entry.key
    return None


def param_specs(p_shapes, cfg, multi_pod: bool, axis_sizes=None):
    """PartitionSpec pytree matching ``model.init``'s parameter tree.

    ``p_shapes`` is the ``jax.eval_shape(model.init, ...)`` tree; segment
    leaves carry the leading layer-stack axis, which always stays
    unsharded (it is scanned over).

    ``axis_sizes`` (optional ``{axis: size}``, see
    :func:`mesh_axis_sizes`) fits the one rule table to a concrete mesh:
    dimensions a shrunken axis no longer divides fall back to replicated
    instead of failing partitioning — the elastic tier's submeshes reuse
    this table verbatim. ``tests/test_elastic.py`` pins that fitting to
    the original shape is the identity.
    """
    from repro.launch.mesh import dp_axes as _dp
    dp = _dp(multi_pod)

    def spec(path, leaf):
        name = _leaf_name(path)
        stacked = any(isinstance(e, jax.tree_util.DictKey)
                      and e.key == "segments" for e in path)
        if stacked:
            return P(None, *_fit(_rule(name, leaf.ndim - 1, dp),
                                 leaf.shape[1:], axis_sizes))
        return P(*_fit(_rule(name, leaf.ndim, dp), leaf.shape, axis_sizes))

    return jax.tree_util.tree_map_with_path(spec, p_shapes)


def opt_specs(opt_shapes, p_spec):
    """Adam state specs: moments mirror the parameter sharding, the step
    counter is replicated. ``opt_shapes`` must be the AdamState-like
    container with ``step``/``mu``/``nu`` fields."""
    return type(opt_shapes)(step=P(), mu=jax.tree.map(lambda s: s, p_spec),
                            nu=jax.tree.map(lambda s: s, p_spec))


def batch_spec(global_batch: int, mesh, multi_pod: bool):
    """Spec *entry* for the example axis: the DP axes when the batch
    divides the DP degree, else ``None`` (replicated small batches,
    e.g. B=1 long-context serving)."""
    from repro.launch.mesh import dp_axes as _dp, dp_degree
    dp = _dp(multi_pod)
    if global_batch % dp_degree(mesh, multi_pod) != 0:
        return None
    return tuple(dp) if len(dp) > 1 else dp[0]


def cache_specs(cache_shapes, cfg, mesh, multi_pod: bool):
    """Decode-cache specs: batch axis (dim 1, after the layer stack) over
    the DP axes when divisible; everything else replicated."""
    from repro.launch.mesh import dp_axes as _dp, dp_degree
    dp = _dp(multi_pod)
    degree = dp_degree(mesh, multi_pod)
    dp_entry = tuple(dp) if len(dp) > 1 else dp[0]

    def spec(leaf):
        if leaf.ndim >= 2 and leaf.shape[1] % degree == 0:
            return P(None, dp_entry, *(None,) * (leaf.ndim - 2))
        return P(*(None,) * leaf.ndim)

    return jax.tree.map(spec, cache_shapes)


def paged_cache_specs(pool_shapes, cfg, mesh, multi_pod: bool):
    """Paged-pool specs (``Model.init_paged_state`` trees).

    Dim 1 after the layer stack is the *page* axis for attention pools
    and the *slot* axis for Mamba caches — both are the serving analogue
    of the decode batch (each page/slot belongs to exactly one sequence),
    so the same rule applies: shard it over the DP axes when divisible,
    replicate otherwise. The block table itself stays host-side and never
    enters the compiled program.
    """
    return cache_specs(pool_shapes, cfg, mesh, multi_pod)
