"""AdamW + cosine schedule + global-norm clipping — pure pytree functions.

Moments are fp32 and shard exactly like their parameters (the sharding
rules put data axes on every large leaf, so this is ZeRO-equivalent:
optimizer state is fully partitioned across the machine). No fp32 master
copy is kept — at 671B params the master would cost an extra 2.6 GB/chip
on the production mesh; bf16 params + fp32 moments is the memory point
that fits 16 GB HBM (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_lr"]


class AdamWState(NamedTuple):
    step: jax.Array       # () int32
    mu: Any               # fp32 pytree
    nu: Any               # fp32 pytree


def adamw_init(params: Any, moment_dtype=jnp.float32) -> AdamWState:
    """``moment_dtype=bfloat16`` halves optimizer HBM twice over — the
    knob that makes 671B-scale training fit v5e (update math stays fp32;
    only the stored moments are rounded)."""
    dt = jnp.dtype(moment_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def cosine_lr(step: jax.Array, base_lr: float = 3e-4, warmup: int = 100,
              total: int = 10_000, min_frac: float = 0.1) -> jax.Array:
    """Linear warmup -> cosine decay to ``min_frac * base_lr``."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(step < warmup, warm, cos)


def _global_norm(tree: Any) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def adamw_update(grads: Any, state: AdamWState, params: Any,
                 lr: jax.Array | float, *, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 clip_norm: float = 1.0) -> tuple[Any, AdamWState, jax.Array]:
    """One AdamW step. Weight decay is masked off 1-D leaves (norms,
    biases, scalars) following standard practice. Returns
    (new_params, new_state, pre-clip grad norm)."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-12))
    step = state.step + 1
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        mdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m = (b1 * m.astype(jnp.float32) + (1 - b1) * g)
        v = (b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g))
        update = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
        if p.ndim > 1 and weight_decay:
            update = update + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return new_p, m.astype(mdt), v.astype(mdt)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), gnorm
