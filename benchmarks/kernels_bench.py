"""Kernel microbenchmarks: Pallas (interpret=CPU semantics) vs pure-jnp
reference wall time and agreement. On TPU the same harness times the
Mosaic-compiled kernels (interpret=False)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import flash_attention, rmsnorm, ssd_scan
from repro.kernels.ref import flash_attention_ref, rmsnorm_ref, ssd_scan_ref

from .common import save_csv, timed

HEADER = "name,us_per_call,derived"


def run(quick: bool = True) -> list[str]:
    rng = np.random.default_rng(0)
    rows = []

    # flash attention
    b, h, kv, s, d = 1, 4, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, kv, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, kv, s, d)), jnp.bfloat16)
    out, us_k = timed(lambda: flash_attention(q, k, v, interpret=True
                                              ).block_until_ready(), repeat=2)
    ref, us_r = timed(lambda: flash_attention_ref(q, k, v
                                                  ).block_until_ready(),
                      repeat=2)
    err = float(jnp.abs(out.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    rows.append(f"kernel[flash {b}x{h}x{s}x{d}],{us_k:.0f},"
                f"ref_us={us_r:.0f};max_err={err:.2e}")

    # ssd scan
    b, h, g, s, p, n = 1, 4, 1, 512, 64, 128
    x = jnp.asarray(rng.normal(size=(b, h, s, p)), jnp.bfloat16)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, h, s)), jnp.float32)
    a_log = jnp.asarray(np.log(np.arange(1, h + 1)), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, g, s, n)), jnp.bfloat16)
    cc = jnp.asarray(rng.normal(size=(b, g, s, n)), jnp.bfloat16)
    (y, _), us_k = timed(lambda: ssd_scan(x, dt, a_log, bb, cc,
                                          interpret=True), repeat=2)
    (yr, _), us_r = timed(lambda: ssd_scan_ref(
        x, dt, -jnp.exp(a_log), jnp.repeat(bb, h // g, 1),
        jnp.repeat(cc, h // g, 1)), repeat=2)
    err = float(jnp.abs(y.astype(jnp.float32)
                        - yr.astype(jnp.float32)).max())
    rows.append(f"kernel[ssd {b}x{h}x{s}x{p}x{n}],{us_k:.0f},"
                f"ref_us={us_r:.0f};max_err={err:.2e}")

    # rmsnorm
    x2 = jnp.asarray(rng.normal(size=(4096, 2048)), jnp.bfloat16)
    w = jnp.ones((2048,), jnp.float32)
    o, us_k = timed(lambda: rmsnorm(x2, w, interpret=True
                                    ).block_until_ready(), repeat=2)
    orf, us_r = timed(lambda: rmsnorm_ref(x2, w).block_until_ready(),
                      repeat=2)
    err = float(jnp.abs(o.astype(jnp.float32)
                        - orf.astype(jnp.float32)).max())
    rows.append(f"kernel[rmsnorm 4096x2048],{us_k:.0f},"
                f"ref_us={us_r:.0f};max_err={err:.2e}")
    save_csv("kernels_bench", rows, HEADER)
    return rows
