"""Shared benchmark plumbing: timing + CSV emission."""
from __future__ import annotations

import time
from pathlib import Path

RESULTS = Path(__file__).parent / "results"


def timed(fn, *args, repeat: int = 3, **kw):
    """(result, best microseconds per call)."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return out, best


def emit(rows: list[str], header: str | None = None) -> list[str]:
    if header:
        print(header)
    for r in rows:
        print(r)
    return rows


def save_csv(name: str, rows: list[str], header: str) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.csv").write_text("\n".join([header, *rows]) + "\n")
