"""Fig. 4 — average endurable failure count mu(N, r) by redundancy:
closed form (Thm. 4.1) vs Monte-Carlo placement simulation."""
from __future__ import annotations

from repro.core.montecarlo import run_montecarlo
from repro.core.theory import mu

from .common import save_csv, timed

HEADER = "name,us_per_call,derived"


def run(quick: bool = True) -> list[str]:
    rows = []
    trials = 60 if quick else 1000
    grid = {
        200: ([3, 6, 9, 12] if quick else list(range(2, 13))),
        600: ([4, 8, 14, 20] if quick else list(range(2, 21))),
        1000: ([5, 9, 17, 26] if quick else list(range(2, 27))),
    }
    for n, rs in grid.items():
        for r in rs:
            res, us = timed(run_montecarlo, n, r, trials=trials, seed=1,
                            repeat=1)
            theory = mu(n, r)
            err = abs(res.mean_failures - theory) / theory
            rows.append(
                f"fig4_mu[N={n} r={r}],{us:.0f},"
                f"mc={res.mean_failures:.1f};theory={theory:.1f};"
                f"rel_err={err:.3f}")
    save_csv("fig4_mu", rows, HEADER)
    return rows
