"""Telemetry overhead benchmark: steps/s with tracing on vs off.

Runs the real repro.exec mesh training loop (default: 4 DP groups x
TP 2 on 8 emulated host devices) three ways over interleaved rounds on
ONE executor — same executable, same prefetch state, same schedule —
toggling only ``executor.telemetry`` between rounds:

* ``off``     — ``telemetry=None``: the allocation-free null path;
* ``metrics`` — ``Telemetry(trace=False)``: counters/gauges/histograms
  plus the per-step HLO wire accounting, no span recording;
* ``trace``   — ``Telemetry()``: full span recording on top.

Rounds interleave (off, metrics, trace, off, metrics, trace, ...) so
machine drift cancels; the reported number is the BEST steps/s per
mode (the min-time estimator — intermittent host stalls land on some
rounds of every mode and best-of discards them, where a mean/median
would fold scheduler noise into a fake "overhead").

``--max-overhead-pct 2`` is the CI gate: full tracing must cost < 2%
steps/s against telemetry-off. Deep mode
(``--trace-deep``) is deliberately NOT measured here — it changes the
compiled program and is excluded from the gate by design.

The warmup runs with telemetry ON so the one-time per-``S_A`` costs
(executable compile, the ``compiled_step_text`` lowering behind the
wire-byte gauges) are paid before any timed round.

Appends one record to ``BENCH_obs_overhead.json`` at the repo root.

Usage:
  python benchmarks/obs_overhead_bench.py [--steps 16] [--rounds 5]
      [--n-groups 4] [--model-degree 2] [--arch qwen2.5-3b]
      [--max-overhead-pct 2]
"""
import argparse
import json
import os
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def force_device_count(n: int) -> None:
    """Append the host-platform fan-out to XLA_FLAGS (preserving any
    flags already set) — must run before the first jax import."""
    flag = f"--xla_force_host_platform_device_count={n}"
    existing = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in existing:
        os.environ["XLA_FLAGS"] = f"{existing} {flag}".strip()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=16,
                    help="steps per timed round")
    ap.add_argument("--rounds", type=int, default=5,
                    help="interleaved rounds per mode (best reported)")
    ap.add_argument("--n-groups", type=int, default=4)
    ap.add_argument("--model-degree", type=int, default=2)
    ap.add_argument("--redundancy", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--per-type-batch", type=int, default=2)
    ap.add_argument("--sync", default="shard_map")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-overhead-pct", type=float, default=None,
                    help="CI gate: fail if full tracing costs more than "
                         "this %% steps/s vs telemetry-off")
    ap.add_argument("--out", default=str(ROOT / "BENCH_obs_overhead.json"))
    args = ap.parse_args()

    force_device_count(args.n_groups * args.model_degree)

    from repro.configs import smoke_config
    from repro.exec import MeshExecutor
    from repro.obs import Telemetry

    cfg = smoke_config(args.arch).scaled(grad_accum=1)
    ex = MeshExecutor(cfg, n_groups=args.n_groups,
                      redundancy=args.redundancy,
                      model_degree=args.model_degree, sync=args.sync,
                      seq=args.seq, per_type_batch=args.per_type_batch,
                      total_steps=10_000, seed=args.seed)

    def run_mode(mode: str) -> float:
        """steps/s for one round; only executor.telemetry differs."""
        ex.telemetry = (None if mode == "off" else
                        Telemetry(trace=(mode == "trace")))
        t0 = time.perf_counter()
        ex.run(args.steps)
        return args.steps / (time.perf_counter() - t0)

    # warmup with telemetry ON: compile + the per-S_A HLO wire
    # accounting (compiled_step_text lowering) happen here, not in a
    # timed round
    ex.telemetry = Telemetry()
    ex.run(2)

    modes = ("off", "metrics", "trace")
    rates: dict[str, list[float]] = {m: [] for m in modes}
    for rnd in range(args.rounds):
        for m in modes:
            rates[m].append(run_mode(m))
        print(f"[round {rnd}] " + "  ".join(
            f"{m}={rates[m][-1]:.2f}/s" for m in modes))

    med = {m: max(rates[m]) for m in modes}
    overhead = {m: 100.0 * (med["off"] - med[m]) / med["off"]
                for m in ("metrics", "trace")}
    rec = {
        "bench": "obs_overhead",
        "arch": args.arch,
        "mesh": f"{args.n_groups}x{args.model_degree}/{args.sync}",
        "steps_per_round": args.steps,
        "rounds": args.rounds,
        "steps_per_s": {m: round(med[m], 3) for m in modes},   # best-of
        "all_rounds": {m: [round(v, 3) for v in rates[m]]
                       for m in modes},
        "overhead_pct": {m: round(overhead[m], 3)
                         for m in ("metrics", "trace")},
    }
    out = Path(args.out)
    history = json.loads(out.read_text()) if out.exists() else []
    history.append(rec)
    out.write_text(json.dumps(history, indent=1))
    print(json.dumps(rec, indent=1))

    if args.max_overhead_pct is not None:
        worst = max(overhead.values())
        assert worst < args.max_overhead_pct, (
            f"telemetry overhead {worst:.2f}% >= gate "
            f"{args.max_overhead_pct}% — {rec['overhead_pct']}")
        print(f"[gate] telemetry overhead {worst:.2f}% < "
              f"{args.max_overhead_pct}% OK")


if __name__ == "__main__":
    main()
