"""Fig. 8 — average stacks computed per training step (empirical
computation overhead) vs the Eq.-5 prediction; paper reports <= 4 %
absolute error."""
from __future__ import annotations

from repro.core.theory import s_bar
from repro.des import DESParams, get_scheme

from .common import save_csv, timed

HEADER = "name,us_per_call,derived"


def run(quick: bool = True) -> list[str]:
    rows = []
    steps = 1200 if quick else 10_000
    ns = (200,) if quick else (200, 600, 1000)
    for n in ns:
        p = DESParams(n=n, steps=steps)
        for r in (3, 6, 9, 12):
            res, us = timed(get_scheme("spare", r=r).simulate,
                            p, seed=0, repeat=1)
            pred = s_bar(n, r)
            rows.append(
                f"fig8_stacks[N={n} r={r}],{us:.0f},"
                f"sim={res.avg_stacks:.3f};eq5={pred:.3f};"
                f"abs_err={abs(res.avg_stacks - pred):.3f}")
    save_csv("fig8_stacks", rows, HEADER)
    return rows
