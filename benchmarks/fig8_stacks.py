"""Fig. 8 — average stacks computed per training step (empirical
computation overhead) vs the Eq.-5 prediction; paper reports <= 4 %
absolute error. Campaign-runner backed (``--jobs``)."""
from __future__ import annotations

from repro.core.theory import s_bar
from repro.scenarios import CampaignSpec, run_campaign

from .common import save_csv

HEADER = "name,us_per_call,derived"


def run(quick: bool = True, jobs: int = 1) -> list[str]:
    steps = 1200 if quick else 10_000
    ns = [200] if quick else [200, 600, 1000]
    spec = CampaignSpec(name="fig8", schemes=["spare"], ns=ns,
                        rs=[3, 6, 9, 12],
                        models=[{"kind": "weibull", "label": "weibull"}],
                        seeds=[0], steps=steps)
    results = run_campaign(spec.cells(), jobs=jobs)
    cells = {(row["n"], row["r"]): row for row in results}

    rows = []
    for n in ns:
        for r in (3, 6, 9, 12):
            res = cells[(n, r)]
            pred = s_bar(n, r)
            rows.append(
                f"fig8_stacks[N={n} r={r}],{res['elapsed_s'] * 1e6:.0f},"
                f"sim={res['avg_stacks']:.3f};eq5={pred:.3f};"
                f"abs_err={abs(res['avg_stacks'] - pred):.3f}")
    save_csv("fig8_stacks", rows, HEADER)
    return rows
