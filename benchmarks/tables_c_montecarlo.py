"""Tables 4-6 (App. C) — Monte-Carlo validation of mu(N,r) and E[S(U_k)]
against the closed forms; paper reports 1.13 % / 0.60 % MAPE.

The (N, r) cells fan out over the campaign runner's process pool
(``--jobs``); each cell keeps its own fixed seed, so results are
identical at any worker count."""
from __future__ import annotations

import time

from repro.core.montecarlo import run_montecarlo
from repro.core.theory import mu, s_bar_lower
from repro.scenarios import parallel_map

from .common import save_csv

HEADER = "name,us_per_call,derived"

# paper MC columns for spot checks: (N, r) -> (mu_mc, stack_mc)
PAPER_MC = {(200, 9): (106.9, 2.07), (600, 8): (254.9, 2.00),
            (1000, 9): (443.6, 2.00)}


def _mc_cell(n: int, r: int, trials: int, seed: int):
    t0 = time.perf_counter()
    res = run_montecarlo(n, r, trials=trials, seed=seed)
    return res, (time.perf_counter() - t0) * 1e6


def run(quick: bool = True, jobs: int = 1) -> list[str]:
    rows = []
    trials = 80 if quick else 1000
    cells = ([(200, 3), (200, 9), (600, 8), (1000, 9)] if quick else
             [(n, r) for n in (200, 600, 1000)
              for r in range(2, {200: 13, 600: 21, 1000: 27}[n])])
    outs = parallel_map(_mc_cell,
                        [(n, r, trials, 3) for n, r in cells], jobs=jobs)
    mape_mu, mape_s, k = 0.0, 0.0, 0
    for (n, r), (res, us) in zip(cells, outs):
        t_mu, t_s = mu(n, r), s_bar_lower(n, r)
        mape_mu += abs(res.mean_failures - t_mu) / t_mu
        mape_s += abs(res.mean_stack - t_s) / t_s
        k += 1
        paper = PAPER_MC.get((n, r))
        extra = (f";paper_mc={paper[0]}/{paper[1]}" if paper else "")
        rows.append(
            f"tableC[N={n} r={r}],{us:.0f},"
            f"mu_mc={res.mean_failures:.1f};mu_theory={t_mu:.1f};"
            f"stack_mc={res.mean_stack:.3f};stack_theory={t_s:.3f}{extra}")
    rows.append(f"tableC[mape],0,mu_mape={mape_mu / k:.4f};"
                f"stack_mape={mape_s / k:.4f};paper=0.0113/0.0060")
    save_csv("tables_c_montecarlo", rows, HEADER)
    return rows
