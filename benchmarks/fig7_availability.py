"""Fig. 7 — empirical availability of SPARe+CKPT vs the theoretical
projection A*(mu(N,r) m) (Eq. 2)."""
from __future__ import annotations

from repro.core.theory import SystemTimes, availability_star, mu
from repro.des import DESParams, get_scheme

from .common import save_csv, timed

HEADER = "name,us_per_call,derived"


def run(quick: bool = True) -> list[str]:
    rows = []
    steps = 1200 if quick else 10_000
    ns = (200,) if quick else (200, 600, 1000)
    times = SystemTimes()
    for n in ns:
        p = DESParams(n=n, steps=steps)
        for r in (3, 6, 9, 12):
            res, us = timed(get_scheme("spare", r=r).simulate,
                            p, seed=0, repeat=1)
            a_theory = availability_star(mu(n, r) * times.mtbf_node,
                                         times.t_save, times.t_restart)
            rows.append(
                f"fig7_avail[N={n} r={r}],{us:.0f},"
                f"sim={res.availability:.4f};theory={a_theory:.4f}")
    save_csv("fig7_availability", rows, HEADER)
    return rows
