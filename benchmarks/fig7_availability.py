"""Fig. 7 — empirical availability of SPARe+CKPT vs the theoretical
projection A*(mu(N,r) m) (Eq. 2). Campaign-runner backed (``--jobs``)."""
from __future__ import annotations

from repro.core.theory import SystemTimes, availability_star, mu
from repro.scenarios import CampaignSpec, run_campaign

from .common import save_csv

HEADER = "name,us_per_call,derived"


def run(quick: bool = True, jobs: int = 1) -> list[str]:
    steps = 1200 if quick else 10_000
    ns = [200] if quick else [200, 600, 1000]
    times = SystemTimes()
    spec = CampaignSpec(name="fig7", schemes=["spare"], ns=ns,
                        rs=[3, 6, 9, 12],
                        models=[{"kind": "weibull", "label": "weibull"}],
                        seeds=[0], steps=steps)
    results = run_campaign(spec.cells(), jobs=jobs)
    cells = {(row["n"], row["r"]): row for row in results}

    rows = []
    for n in ns:
        for r in (3, 6, 9, 12):
            res = cells[(n, r)]
            a_theory = availability_star(mu(n, r) * times.mtbf_node,
                                         times.t_save, times.t_restart)
            rows.append(
                f"fig7_avail[N={n} r={r}],{res['elapsed_s'] * 1e6:.0f},"
                f"sim={res['availability']:.4f};theory={a_theory:.4f}")
    save_csv("fig7_availability", rows, HEADER)
    return rows
