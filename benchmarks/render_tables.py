"""Render EXPERIMENTS.md tables from the dry-run JSONs.

  PYTHONPATH=src python -m benchmarks.render_tables [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

DRYRUN = Path(__file__).parent / "results" / "dryrun"


def rows(mesh: str, variant: str = "baseline"):
    out = []
    for f in sorted(glob.glob(str(DRYRUN / f"*__{mesh}__{variant}.json"))):
        out.append(json.load(open(f)))
    return out


def roofline_table(mesh: str) -> str:
    lines = [
        "| arch | shape | peak GiB | useful | compute s | memory s (lb-ub) "
        "| collective s | bottleneck | roofline frac (ub / lb) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows(mesh), key=lambda r: (r["arch"], r["shape"])):
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| skip (long-context) | — |")
            continue
        t = r["roofline"]
        useful_s = r["model_flops_per_device"] / 197e12
        dom_ub = max(t["compute_s"], t["memory_s"], t["collective_s"])
        dom_lb = max(t["compute_s"], t["memory_lb_s"], t["collective_s"])
        frac_ub = useful_s / dom_ub if dom_ub else 0
        frac_lb = useful_s / dom_lb if dom_lb else 0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['peak_bytes'] / 2**30:.1f} "
            f"| {r['useful_flops_ratio']:.2f} | {t['compute_s']:.2f} "
            f"| {t['memory_lb_s']:.1f}–{t['memory_s']:.1f} "
            f"| {t['collective_s']:.2f} | {r['bottleneck'].replace('_s', '')} "
            f"| {frac_ub:.3f} / {frac_lb:.3f} |")
    return "\n".join(lines)


def dryrun_table() -> str:
    lines = [
        "| arch | shape | 16x16 | 2x16x16 | compile s (sp/mp) "
        "| peak GiB (sp/mp) |",
        "|---|---|---|---|---|---|",
    ]
    sp = {(r["arch"], r["shape"]): r for r in rows("16x16")}
    mp = {(r["arch"], r["shape"]): r for r in rows("2x16x16")}
    for key in sorted(sp):
        a, b = sp[key], mp.get(key, {})
        def st(r):
            if not r:
                return "—"
            return "SKIP" if r.get("skipped") else ("OK" if r.get("ok")
                                                    else "FAIL")
        cs = (f"{a.get('compile_s', 0):.0f}/{b.get('compile_s', 0):.0f}"
              if not a.get("skipped") else "—")
        pk = (f"{a.get('peak_bytes', 0) / 2**30:.1f}/"
              f"{b.get('peak_bytes', 0) / 2**30:.1f}"
              if not a.get("skipped") else "—")
        lines.append(f"| {key[0]} | {key[1]} | {st(a)} | {st(b)} "
                     f"| {cs} | {pk} |")
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--what", default="both",
                    choices=["roofline", "dryrun", "both"])
    args = ap.parse_args()
    if args.what in ("dryrun", "both"):
        print("### Dry-run matrix\n")
        print(dryrun_table())
        print()
    if args.what in ("roofline", "both"):
        print(f"### Roofline ({args.mesh})\n")
        print(roofline_table(args.mesh))
