"""Fig. 6 — normalized time-to-train J(r): SPARe+CKPT vs Rep+CKPT from the
discrete-event simulation, with the Eq.-7 theory curve."""
from __future__ import annotations

from repro.core.theory import j_normalized
from repro.des import DESParams, get_scheme

from .common import save_csv, timed

HEADER = "name,us_per_call,derived"


def run(quick: bool = True) -> list[str]:
    rows = []
    steps = 1200 if quick else 10_000
    seeds = (0,) if quick else (0, 1, 2)
    ns = (200,) if quick else (200, 600, 1000)
    for n in ns:
        p = DESParams(n=n, steps=steps)
        for r in (2, 3, 4, 6):
            vals = []
            us = 0.0
            for s in seeds:
                res, t = timed(get_scheme("replication", r=r).simulate,
                               p, seed=s, repeat=1)
                vals.append(res.ttt_norm)
                us += t
            rows.append(
                f"fig6_rep[N={n} r={r}],{us / len(seeds):.0f},"
                f"ttt={sum(vals) / len(vals):.3f}")
        for r in (2, 3, 4, 6, 9, 12):
            vals = []
            us = 0.0
            for s in seeds:
                res, t = timed(get_scheme("spare", r=r).simulate,
                               p, seed=s, repeat=1)
                vals.append(res.ttt_norm)
                us += t
            rows.append(
                f"fig6_spare[N={n} r={r}],{us / len(seeds):.0f},"
                f"ttt={sum(vals) / len(vals):.3f};"
                f"theory_J={j_normalized(r, n):.3f}")
    save_csv("fig6_time_to_train", rows, HEADER)
    return rows
