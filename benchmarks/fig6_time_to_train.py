"""Fig. 6 — normalized time-to-train J(r): SPARe+CKPT vs Rep+CKPT from the
discrete-event simulation, with the Eq.-7 theory curve.

Runs on the scenario-campaign runner (process-parallel with ``--jobs``,
deterministic per-cell seeding)."""
from __future__ import annotations

from repro.core.theory import j_normalized
from repro.scenarios import CampaignSpec, run_campaign

from .common import save_csv

HEADER = "name,us_per_call,derived"

_MODEL = [{"kind": "weibull", "label": "weibull"}]


def run(quick: bool = True, jobs: int = 1) -> list[str]:
    steps = 1200 if quick else 10_000
    seeds = [0] if quick else [0, 1, 2]
    ns = [200] if quick else [200, 600, 1000]
    rep = CampaignSpec(name="fig6_rep", schemes=["replication"], ns=ns,
                       rs=[2, 3, 4, 6], models=_MODEL, seeds=seeds,
                       steps=steps)
    spare = CampaignSpec(name="fig6_spare", schemes=["spare"], ns=ns,
                         rs=[2, 3, 4, 6, 9, 12], models=_MODEL, seeds=seeds,
                         steps=steps)
    results = run_campaign(rep.cells() + spare.cells(), jobs=jobs)

    cells: dict[tuple, list[dict]] = {}
    for row in results:
        cells.setdefault((row["scheme"], row["n"], row["r"]), []).append(row)

    def _mean(group: list[dict], field: str) -> float:
        return sum(r[field] for r in group) / len(group)

    rows = []
    for n in ns:
        for r in (2, 3, 4, 6):
            g = cells[("replication", n, r)]
            rows.append(
                f"fig6_rep[N={n} r={r}],{_mean(g, 'elapsed_s') * 1e6:.0f},"
                f"ttt={_mean(g, 'ttt_norm'):.3f}")
        for r in (2, 3, 4, 6, 9, 12):
            g = cells[("spare", n, r)]
            rows.append(
                f"fig6_spare[N={n} r={r}],{_mean(g, 'elapsed_s') * 1e6:.0f},"
                f"ttt={_mean(g, 'ttt_norm'):.3f};"
                f"theory_J={j_normalized(r, n):.3f}")
    save_csv("fig6_time_to_train", rows, HEADER)
    return rows
