"""RECTLR controller latency microbenchmark (App. D claims sub-100 ms at
N ~ 1e3 — we measure the actual phases on realistic failure trails)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import Rectlr, SpareState

from .common import save_csv

HEADER = "name,us_per_call,derived"


def run(quick: bool = True) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    for n, r in ((200, 9), (600, 8), (1000, 10)):
        for binary in (False, True):
            st, ctl = SpareState(n, r), Rectlr(binary_search=binary)
            times, hk_calls, reorders = [], 0, 0
            k_max = int(0.4 * n)
            order = rng.permutation(n)[:k_max]
            for w in order:
                out = ctl.on_failures(st, [int(w)])
                if out.wipeout:
                    break
                times.append(out.controller_seconds)
                hk_calls += out.hk_free_calls
                reorders += int(out.reordered)
            mean_us = float(np.mean(times)) * 1e6
            p99_us = float(np.quantile(times, 0.99)) * 1e6
            rows.append(
                f"rectlr[N={n} r={r} bs={int(binary)}],{mean_us:.0f},"
                f"p99_us={p99_us:.0f};events={len(times)};"
                f"reorders={reorders};hk_calls={hk_calls};"
                f"paper_budget_us=100000")
    save_csv("rectlr_bench", rows, HEADER)
    return rows
