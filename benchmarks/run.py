"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (also saved under
``benchmarks/results/*.csv``). ``--full`` uses the paper-scale settings
(10k-step DES horizons, 1000-trial Monte-Carlo) — hours on CPU;
the default quick mode validates every claim at reduced scale in
minutes.

DES/Monte-Carlo suites (fig6/fig7/fig8/tablesC) run on the scenario
campaign runner and fan out across ``--jobs`` worker processes with
deterministic per-cell seeding (results identical at any worker count).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig6,table2]
                                          [--jobs 4]
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time

from . import (
    fig4_mu,
    fig5_overhead,
    fig6_time_to_train,
    fig7_availability,
    fig8_stacks,
    kernels_bench,
    rectlr_bench,
    roofline,
    table2_min_ttt,
    tables_c_montecarlo,
)

SUITES = {
    "fig4": fig4_mu,
    "fig5": fig5_overhead,
    "fig6": fig6_time_to_train,
    "fig7": fig7_availability,
    "fig8": fig8_stacks,
    "table2": table2_min_ttt,
    "tablesC": tables_c_montecarlo,
    "rectlr": rectlr_bench,
    "kernels": kernels_bench,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale horizons/trials (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--jobs", type=int, default=1,
                    help="worker processes for campaign-backed suites")
    args = ap.parse_args()

    names = (args.only.split(",") if args.only else list(SUITES))
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    for name in names:
        if name not in SUITES:
            print(f"# unknown suite {name!r}; have {sorted(SUITES)}",
                  file=sys.stderr)
            continue
        t1 = time.perf_counter()
        run_fn = SUITES[name].run
        kw = ({"jobs": args.jobs}
              if "jobs" in inspect.signature(run_fn).parameters else {})
        for row in run_fn(quick=not args.full, **kw):
            print(row)
        print(f"# {name} done in {time.perf_counter() - t1:.1f}s", file=sys.stderr)
    print(f"# all suites done in {time.perf_counter() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
