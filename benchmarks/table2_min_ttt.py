"""Table 2 — minimum time-to-train: best-r SPARe+CKPT vs best-r Rep+CKPT
(the paper's headline 40-52 % gain)."""
from __future__ import annotations

from repro.des import DESParams, get_scheme

from .common import save_csv, timed

HEADER = "name,us_per_call,derived"

# paper Table 2 reference values (ttt/T0, availability %, gain %)
PAPER = {200: (6.07, 2.92, 51.9), 600: (4.27, 2.49, 41.7),
         1000: (3.88, 2.34, 39.6)}


def run(quick: bool = True) -> list[str]:
    rows = []
    steps = 1500 if quick else 10_000
    seeds = (0,) if quick else (0, 1, 2)
    ns = (200, 600) if quick else (200, 600, 1000)
    for n in ns:
        p = DESParams(n=n, steps=steps)
        us_total = 0.0

        def best(scheme_name, rs):
            nonlocal us_total
            out = []
            for r in rs:
                accs = []
                for s in seeds:
                    res, us = timed(get_scheme(scheme_name, r=r).simulate,
                                    p, seed=s, repeat=1)
                    us_total += us
                    accs.append(res)
                ttt = sum(a.ttt_norm for a in accs) / len(accs)
                avail = sum(a.availability for a in accs) / len(accs)
                out.append((ttt, avail, r))
            return min(out)

        rep = best("replication", (2, 3, 4))
        spare = best("spare", ((6, 9, 12) if quick
                               else tuple(range(4, 15))))
        adaptive = best("adaptive", (spare[2],))
        gain = (1 - spare[0] / rep[0]) * 100
        ref = PAPER.get(n, (0, 0, 0))
        rows.append(
            f"table2[N={n}],{us_total:.0f},"
            f"rep_best=r{rep[2]}:{rep[0]:.2f}@{rep[1] * 100:.1f}%;"
            f"spare_best=r{spare[2]}:{spare[0]:.2f}@{spare[1] * 100:.1f}%;"
            f"adaptive=r{adaptive[2]}:{adaptive[0]:.2f};"
            f"gain={gain:.1f}%;paper_gain={ref[2]:.1f}%")
    save_csv("table2_min_ttt", rows, HEADER)
    return rows
