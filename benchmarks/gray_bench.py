"""Gray-failure benchmark: tolerate vs proactive SPARe demotion TTT.

Runs the gray campaign (``repro.scenarios.campaign.gray_regime_cells``)
on the live emulated mesh: the SAME scripted fail-slow episode (one DP
group degraded 3x for a fixed poll window, nobody dies) through two
mitigation arms —

* ``tolerate`` — no detector: the synchronous barrier stretches every
  step to the straggler's pace for the whole episode;
* ``demote`` — the online straggler detector flags the group within its
  dwell window, the adaptive scheme's ``decide_degraded`` picks SPARe
  demotion (a pure weight-table edit, both stacking depths pre-warmed so
  zero run-attributed recompiles), and the group is re-admitted
  bit-identically once the episode heals.

The demote arm is traced; the record carries the ``launch.obs``
recovery-attribution rows so ``demote`` / ``readmit`` kinds show up in
the same table that attributes masks, restarts, and reshapes.

Appends one record per invocation to ``BENCH_gray.json`` at the repo
root. ``--assert-min-speedup`` is the CI gate: the detector must flag
within the dwell window, demotion must restore at least
``--min-steprate`` (default 0.9) of the healthy step rate with zero
run-attributed recompiles, re-admission must be bit-identical to a
never-demoted weight table, and the demote arm's modeled TTT must be
strictly below tolerate's.

Usage:
  python benchmarks/gray_bench.py [--steps 32] [--n-groups 8]
      [--slow-step 4] [--heal-step 16] [--slow-factor 3.0]
      [--seconds-per-step 64] [--min-steprate 0.9]
      [--assert-min-speedup] [--arch qwen2.5-3b]
"""
import argparse
import json
import os
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def force_device_count(n: int) -> None:
    """Append the host-platform fan-out to XLA_FLAGS (preserving any
    flags already set) — must run before the first jax import."""
    flag = f"--xla_force_host_platform_device_count={n}"
    existing = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in existing:
        os.environ["XLA_FLAGS"] = f"{existing} {flag}".strip()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--n-groups", type=int, default=8)
    ap.add_argument("--redundancy", type=int, default=2)
    ap.add_argument("--model-degree", type=int, default=1)
    ap.add_argument("--slow-group", type=int, default=0)
    ap.add_argument("--slow-factor", type=float, default=3.0)
    ap.add_argument("--slow-step", type=int, default=4)
    ap.add_argument("--heal-step", type=int, default=16)
    ap.add_argument("--seconds-per-step", type=float, default=64.0)
    ap.add_argument("--t-restart", type=float, default=3600.0)
    ap.add_argument("--min-steprate", type=float, default=0.9,
                    help="fraction of the healthy step rate demotion "
                         "must restore while the episode persists")
    ap.add_argument("--assert-min-speedup", action="store_true",
                    help="fail unless the detector flags in time, "
                         "demotion restores the step rate with zero "
                         "recompiles, re-admission is bit-identical, and "
                         "demote beats tolerate on modeled TTT")
    ap.add_argument("--out", default=str(ROOT / "BENCH_gray.json"))
    args = ap.parse_args()

    force_device_count(args.n_groups * args.model_degree)

    from repro.launch import obs as obs_cli
    from repro.obs import load_trace
    from repro.scenarios.campaign import gray_regime_cells, run_gray_cell

    with tempfile.TemporaryDirectory(prefix="gray-bench-") as td:
        cells = gray_regime_cells(
            arch=args.arch, n=args.n_groups, r=args.redundancy,
            steps=args.steps, slow_group=args.slow_group,
            slow_factor=args.slow_factor, slow_step=args.slow_step,
            heal_step=args.heal_step,
            model_degree=args.model_degree,
            seconds_per_step=args.seconds_per_step,
            t_restart=args.t_restart, trace_dir=td)
        rows = {}
        attribution = None
        for cell in cells:
            row = run_gray_cell(cell)
            rows[row["arm"]] = row
            print(f"[gray] {row['arm']:>8}: steps={row['steps_done']} "
                  f"demotes={row['demotes']} readmits={row['readmits']} "
                  f"flag@{row['flag_step']} ttt={row['ttt_s']:.0f}s "
                  f"recompiles={row['recompiles']}")
            if cell["arm"] == "demote":
                attribution = obs_cli.attribution_table(
                    load_trace(cell["trace"]))

    dm, tol = rows["demote"], rows["tolerate"]
    rec = {
        "bench": "gray",
        "arch": args.arch,
        "mesh": f"{args.n_groups}x{args.model_degree}",
        "r": args.redundancy,
        "steps": args.steps,
        "slow": {"group": args.slow_group, "factor": args.slow_factor,
                 "window": [args.slow_step, args.heal_step]},
        "seconds_per_step": args.seconds_per_step,
        "arms": rows,
        "demote_vs_tolerate_ttt_x": round(
            tol["ttt_s"] / max(dm["ttt_s"], 1e-9), 3),
        "attribution": attribution,
    }

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    history = json.loads(out.read_text()) if out.exists() else []
    history.append(rec)
    out.write_text(json.dumps(history, indent=1))
    print(json.dumps(rec, indent=1))

    if args.assert_min_speedup:
        # detector latency: warmup + flag dwell after the episode onset
        # (the scripted window starts at --slow-step)
        from repro.health import StragglerDetector
        det = StragglerDetector(args.n_groups)
        dwell_budget = args.slow_step + det.warmup + det.min_dwell + 1
        assert dm["demotes"] >= 1 and dm["demote_step"] is not None, \
            "demote arm never demoted"
        assert dm["flag_step"] is not None \
            and dm["flag_step"] <= dwell_budget, (
            f"detector flagged at {dm['flag_step']}, after the dwell "
            f"budget (step {dwell_budget})")
        assert dm["wipeouts"] == 0 and tol["wipeouts"] == 0, \
            "gray arms must not wipe out (nobody dies)"
        rate = (dm["healthy_window_s"]
                / max(dm["post_demote_window_max"] or float("inf"), 1e-9))
        assert rate >= args.min_steprate, (
            f"demotion restored only {rate:.2f}x of the healthy step "
            f"rate (< {args.min_steprate})")
        assert dm["recompiles"] == 0, (
            f"demote round trip cost {dm['recompiles']} run-attributed "
            f"recompiles (pre-warm should freeze this at zero)")
        assert dm["readmits"] >= 1 and dm["readmit_identical"], \
            "re-admitted weight table must match a never-demoted run"
        assert dm["ttt_s"] < tol["ttt_s"], (
            f"demote TTT {dm['ttt_s']:.0f}s did not beat tolerate "
            f"{tol['ttt_s']:.0f}s")
        kinds = [r["kind"] for r in (attribution or [])]
        assert "demote" in kinds and "readmit" in kinds, (
            f"obs attribution table missed the demote/readmit round "
            f"trip: {kinds}")
        print(f"[gray] OK: demote beats tolerate "
              f"{rec['demote_vs_tolerate_ttt_x']}x on modeled TTT, "
              f"step rate restored to {rate:.2f}x healthy")


if __name__ == "__main__":
    main()
