"""SPMD gradient-sync benchmark: steps/s and wire bytes, masked vs unmasked.

Runs the real repro.exec mesh step (default: 4 DP groups x TP 2 on 8
emulated host devices — ``main`` forces the host-platform device count
to ``n_groups * model_degree`` before the first jax import, which only
happens inside ``main``) and measures the paper's headline property end
to end:

* throughput of the healthy schedule vs the same schedule after a
  masked failure + RECTLR reorder (identical S_A so the executable is
  shared — masking is weight data, recompiles are impossible);
* per-step collective count and ring-algorithm wire bytes parsed from
  the compiled HLO (repro/launch/hlo.py) for both schedules — the
  zero-extra-collectives claim as numbers, not prose;
* with ``--grad-compress int8_ef``: the same two schedules through the
  compressed bucketed sync, plus the wire-byte ratio against an
  uncompressed executor's step at the same S_A — the ~4x traffic-drop
  claim as numbers (``--assert-min-ratio 3.5`` is the CI gate).

Appends one record per invocation to ``BENCH_spmd_sync.json`` at the
repo root so CI runs accumulate a perf trajectory across all sync
modes (shard_map, gspmd, shard_map+int8_ef).

Usage:
  python benchmarks/spmd_sync_bench.py [--steps 8] [--n-groups 4]
      [--model-degree 2] [--sync shard_map|gspmd]
      [--grad-compress none|int8_ef] [--assert-min-ratio 3.5]
      [--arch qwen2.5-3b]
"""
import argparse
import json
import os
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def force_device_count(n: int) -> None:
    """Append the host-platform fan-out to XLA_FLAGS (preserving any
    flags already set) — must run before the first jax import."""
    flag = f"--xla_force_host_platform_device_count={n}"
    existing = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in existing:
        os.environ["XLA_FLAGS"] = f"{existing} {flag}".strip()


def _steps_per_s(executor, steps: int) -> float:
    from repro.train.trainer import TrainReport
    report = TrainReport()
    # warm the executable (the step donates params/opt, so reassign);
    # advancing executor.step keeps the prefetch key matching, so the
    # measurement exercises the real double-buffered feeding path
    executor.params, executor.opt_state, _ = executor._dispatch(report)
    executor.step += 1
    t0 = time.perf_counter()
    for _ in range(steps):
        executor.params, executor.opt_state, m = executor._dispatch(report)
        executor.step += 1
    float(m["loss"])                               # block on the result
    return steps / (time.perf_counter() - t0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--n-groups", type=int, default=4)
    ap.add_argument("--model-degree", type=int, default=2)
    ap.add_argument("--sync", default="shard_map",
                    choices=("shard_map", "gspmd"))
    ap.add_argument("--grad-compress", default="none",
                    choices=("none", "int8_ef"),
                    help="int8_ef runs the two-phase compressed bucketed "
                         "sync (shard_map only) and reports the wire-byte "
                         "ratio vs the uncompressed step")
    ap.add_argument("--assert-min-ratio", type=float, default=None,
                    help="fail unless baseline/compressed gradient-sync "
                         "wire bytes >= this factor (e.g. 3.5)")
    ap.add_argument("--out", default=str(ROOT / "BENCH_spmd_sync.json"))
    args = ap.parse_args()

    force_device_count(args.n_groups * args.model_degree)

    from repro.configs import smoke_config
    from repro.core import Rectlr, SpareState
    from repro.exec import MeshExecutor
    from repro.launch.hlo import collective_report, wire_byte_ratio

    compress = None if args.grad_compress == "none" else args.grad_compress
    cfg = smoke_config(args.arch).scaled(grad_accum=1)
    ex = MeshExecutor(cfg, n_groups=args.n_groups, redundancy=2,
                      model_degree=args.model_degree, sync=args.sync,
                      grad_compress=compress,
                      seq=32, per_type_batch=2, total_steps=1000)

    # healthy schedule at the post-failure depth, so both measurements
    # share one executable and differ in weight data only
    masked = SpareState(args.n_groups, 2)
    outcome = Rectlr().on_failures(masked, [0])
    assert not outcome.wipeout
    healthy = SpareState(args.n_groups, 2)
    healthy.s_a = masked.s_a

    ex.state = healthy
    unmasked_sps = _steps_per_s(ex, args.steps)
    ex.state = masked
    masked_sps = _steps_per_s(ex, args.steps)

    text_unmasked = ex.compiled_step_text(state=healthy)
    sync_unmasked = collective_report(text_unmasked)
    sync_masked = collective_report(ex.compiled_step_text(state=masked))

    mode = args.sync if compress is None else f"{args.sync}+{compress}"
    rec = {
        "bench": "spmd_sync",
        "arch": args.arch,
        "mesh": f"{args.n_groups}x{args.model_degree}",
        "sync": args.sync,
        "grad_compress": args.grad_compress,
        "mode": mode,
        "s_a": masked.s_a,
        "steps": args.steps,
        "unmasked": {"steps_per_s": round(unmasked_sps, 3),
                     "collectives": sync_unmasked},
        "masked": {"steps_per_s": round(masked_sps, 3),
                   "collectives": sync_masked},
        "masking_overhead_pct": round(
            100.0 * (unmasked_sps / max(masked_sps, 1e-9) - 1.0), 2),
        "extra_collectives": (
            sync_masked["counts"] != sync_unmasked["counts"]),
    }

    if compress is not None:
        # the ~4x claim: same arch/mesh/S_A, fp32 buckets on the wire
        base = MeshExecutor(cfg, n_groups=args.n_groups, redundancy=2,
                            model_degree=args.model_degree, sync=args.sync,
                            seq=32, per_type_batch=2, total_steps=1000)
        base.state = healthy
        ratio = wire_byte_ratio(text_unmasked,
                                base.compiled_step_text(state=healthy))
        rec["wire_bytes_vs_fp32"] = round(ratio, 4)
        rec["wire_reduction_x"] = round(1.0 / max(ratio, 1e-30), 2)

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    history = json.loads(out.read_text()) if out.exists() else []
    history.append(rec)
    out.write_text(json.dumps(history, indent=1))
    print(json.dumps(rec, indent=1))
    assert not rec["extra_collectives"], \
        "masked step emitted different collectives than unmasked"
    if args.assert_min_ratio is not None:
        assert compress is not None, \
            "--assert-min-ratio needs --grad-compress int8_ef"
        assert rec["wire_reduction_x"] >= args.assert_min_ratio, (
            f"compressed sync only cut wire bytes "
            f"{rec['wire_reduction_x']}x (< {args.assert_min_ratio}x)")


if __name__ == "__main__":
    main()
