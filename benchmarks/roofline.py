"""§Roofline report — three-term roofline per (arch x shape) from the
dry-run artifacts (single-pod mesh per the spec; multi-pod proves the pod
axis shards and is reported in §Dry-run).

Reads ``benchmarks/results/dryrun/*.json``. Re-run the sweep with
``bash benchmarks/run_dryrun_sweep.sh`` if stale.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

from .common import save_csv

HEADER = "name,us_per_call,derived"
DRYRUN = Path(__file__).parent / "results" / "dryrun"


def load_cells(mesh: str = "16x16", variant: str = "baseline") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(str(DRYRUN / f"*__{mesh}__{variant}.json"))):
        r = json.load(open(f))
        if r.get("ok") and not r.get("skipped"):
            cells.append(r)
    return cells


def run(quick: bool = True) -> list[str]:
    rows = []
    cells = load_cells()
    if not cells:
        return [("roofline[missing],0,run benchmarks/run_dryrun_sweep.sh "
                 "first")]
    for r in cells:
        t = r["roofline"]
        dom = r["bottleneck"]
        total = max(t.values())
        frac = {k: v / total for k, v in t.items()}
        rows.append(
            f"roofline[{r['arch']}|{r['shape']}],{r.get('compile_s', 0) * 1e6:.0f},"
            f"compute_s={t['compute_s']:.4f};memory_s={t['memory_s']:.4f};"
            f"collective_s={t['collective_s']:.4f};bottleneck={dom};"
            f"useful_flops_ratio={r['useful_flops_ratio']:.3f};"
            f"peak_gib={r['peak_bytes'] / 2**30:.2f};"
            f"balance={frac['compute_s']:.2f}/{frac['memory_s']:.2f}/"
            f"{frac['collective_s']:.2f}")
    save_csv("roofline", rows, HEADER)
    return rows
