"""Fig. 5 — average computation overhead by redundancy: SPARe's
near-constant S_bar(N, r) (Thm. 4.2) vs traditional replication's r."""
from __future__ import annotations

from repro.core.montecarlo import run_montecarlo
from repro.core.theory import s_bar, s_bar_lower

from .common import save_csv, timed

HEADER = "name,us_per_call,derived"


def run(quick: bool = True) -> list[str]:
    rows = []
    trials = 60 if quick else 1000
    for n in (200, 600, 1000):
        rs = ([3, 9, 20] if quick else range(2, 21))
        for r in rs:
            if r * (r - 1) > n - 1:
                continue
            res, us = timed(run_montecarlo, n, r, trials=trials, seed=2,
                            repeat=1)
            rows.append(
                f"fig5_overhead[N={n} r={r}],{us:.0f},"
                f"mc_stack={res.mean_stack:.3f};"
                f"eq6_lower={s_bar_lower(n, r):.3f};"
                f"eq5_sbar={s_bar(n, r):.3f};replication={float(r):.1f}")
    save_csv("fig5_overhead", rows, HEADER)
    return rows
