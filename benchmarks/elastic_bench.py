"""Elastic recovery-tier benchmark: mask vs reshape vs restart TTT.

Runs the third-regime campaign (``repro.scenarios.campaign
.elastic_regime_cells``) on the live emulated mesh: the SAME
deterministic failure clock hits the three recovery tiers and the arms
are compared on work-normalized time-to-train —

* ``mask`` — single-group kill, RECTLR masks it at full DP (free tier);
* ``reshape`` — an unmaskable adjacent pair on the elastic executor:
  the live TTT policy continues degraded on a survivor submesh, zero
  wipe-outs, one extra executable (the new mesh shape);
* ``restart`` — the identical unmaskable pair on the plain executor:
  rollback + modeled cluster restart, the only pre-elastic option.

The reshape arm is traced; the record carries the ``launch.obs``
recovery-attribution rows so the ``reshape`` kind shows up as numbers
in the same table that attributes masks and restarts.

Appends one record per invocation to ``BENCH_elastic.json`` at the repo
root. ``--assert-elastic`` is the CI gate: the reshape arm must finish
with zero wipe-outs, at most one recompile beyond the new mesh-shape
entry, a lower modeled TTT than the restart arm, and a ``reshape`` row
in the attribution table.

Usage:
  python benchmarks/elastic_bench.py [--steps 24] [--n-groups 8]
      [--fail-step 8] [--seconds-per-step 64] [--t-reshape 60]
      [--t-restart 3600] [--grad-compress none|int8_ef]
      [--assert-elastic] [--arch qwen2.5-3b]
"""
import argparse
import json
import os
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def force_device_count(n: int) -> None:
    """Append the host-platform fan-out to XLA_FLAGS (preserving any
    flags already set) — must run before the first jax import."""
    flag = f"--xla_force_host_platform_device_count={n}"
    existing = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in existing:
        os.environ["XLA_FLAGS"] = f"{existing} {flag}".strip()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--n-groups", type=int, default=8)
    ap.add_argument("--redundancy", type=int, default=2)
    ap.add_argument("--model-degree", type=int, default=1)
    ap.add_argument("--fail-step", type=int, default=8)
    ap.add_argument("--seconds-per-step", type=float, default=64.0)
    ap.add_argument("--t-reshape", type=float, default=60.0)
    ap.add_argument("--t-restart", type=float, default=3600.0)
    ap.add_argument("--grad-compress", default="int8_ef",
                    choices=("none", "int8_ef"))
    ap.add_argument("--assert-elastic", action="store_true",
                    help="fail unless the reshape arm continues degraded "
                         "with zero wipe-outs and beats the restart arm's "
                         "modeled TTT")
    ap.add_argument("--out", default=str(ROOT / "BENCH_elastic.json"))
    args = ap.parse_args()

    force_device_count(args.n_groups * args.model_degree)

    from repro.launch import obs as obs_cli
    from repro.obs import load_trace
    from repro.scenarios.campaign import (elastic_regime_cells,
                                          run_elastic_cell)

    compress = None if args.grad_compress == "none" else args.grad_compress
    with tempfile.TemporaryDirectory(prefix="elastic-bench-") as td:
        cells = elastic_regime_cells(
            arch=args.arch, n=args.n_groups, r=args.redundancy,
            steps=args.steps, fail_step=args.fail_step,
            model_degree=args.model_degree,
            seconds_per_step=args.seconds_per_step,
            t_reshape=args.t_reshape, t_restart=args.t_restart,
            grad_compress=compress, trace_dir=td)
        rows = {}
        attribution = None
        for cell in cells:
            row = run_elastic_cell(cell)
            rows[row["arm"]] = row
            print(f"[elastic] {row['arm']:>7}: dp {args.n_groups}->"
                  f"{row['dp_final']}  wipeouts={row['wipeouts']} "
                  f"reshapes={row['reshapes']} ttt={row['ttt_s']:.0f}s "
                  f"work={row['work_units']:.1f}")
            if cell["arm"] == "reshape":
                attribution = obs_cli.attribution_table(
                    load_trace(cell["trace"]))

    rec = {
        "bench": "elastic",
        "arch": args.arch,
        "mesh": f"{args.n_groups}x{args.model_degree}",
        "r": args.redundancy,
        "steps": args.steps,
        "grad_compress": args.grad_compress,
        "seconds_per_step": args.seconds_per_step,
        "t_reshape": args.t_reshape,
        "t_restart": args.t_restart,
        "arms": rows,
        "reshape_vs_restart_ttt_x": round(
            rows["restart"]["ttt_s"] / max(rows["reshape"]["ttt_s"], 1e-9),
            3),
        "attribution": attribution,
    }

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    history = json.loads(out.read_text()) if out.exists() else []
    history.append(rec)
    out.write_text(json.dumps(history, indent=1))
    print(json.dumps(rec, indent=1))

    if args.assert_elastic:
        rs, rt, mk = rows["reshape"], rows["restart"], rows["mask"]
        assert rs["wipeouts"] == 0, \
            f"reshape arm wiped out {rs['wipeouts']}x"
        assert rs["reshapes"] >= 1, "reshape arm never reshaped"
        assert rs["dp_final"] < args.n_groups, \
            "reshape arm should finish degraded"
        assert rs["recompiles"] <= 2, (
            f"reshape cost {rs['recompiles']} recompiles (> 1 beyond the "
            f"new mesh-shape entry)")
        assert rt["wipeouts"] >= 1, \
            "restart arm must actually wipe (else the arms diverged)"
        assert rs["ttt_s"] < rt["ttt_s"], (
            f"elastic TTT {rs['ttt_s']:.0f}s did not beat restart "
            f"{rt['ttt_s']:.0f}s")
        assert mk["ttt_s"] <= rs["ttt_s"], \
            "masking must stay the cheapest tier"
        kinds = [r["kind"] for r in (attribution or [])]
        assert "reshape" in kinds, (
            f"obs attribution table never saw the reshape: {kinds}")
        print(f"[elastic] OK: reshape beats restart "
              f"{rec['reshape_vs_restart_ttt_x']}x on modeled TTT")


if __name__ == "__main__":
    main()
