"""Serving benchmark: tokens/s and per-token latency, healthy vs burst.

Runs the full serving tier twice on the 8-device emulated host platform
(``main`` forces the device count before the first jax import, matching
the other emulated-mesh benches):

* **healthy** — no failures; every replica serves its weighted share;
* **rack-burst** — a ``correlated`` scope=rack campaign through
  :class:`~repro.train.injection.ScenarioInjector` kills replicas
  mid-serving; survivors absorb the dead replicas' queue share through
  the SPARe weight table (host data — the shared executable cache must
  not miss once after warmup) and requeued in-flight requests restart
  from their prompts.

Both runs serve the identical deterministic
:class:`~repro.data.pipeline.RequestStream` workload, so the bench also
asserts the zero-dropped-requests and bit-identical-outputs gates, then
appends one record (healthy + degraded tokens/s, p50/p99/p99.9
per-token latency ms — exact quantiles via
:func:`repro.obs.metrics.latency_stats` — event log, recompile counter)
to ``BENCH_serving.json`` at the repo root.

Usage:
  python benchmarks/serving_bench.py [--arch qwen2.5-3b] [--requests 16]
      [--replicas 3] [--slots 2] [--max-new 6] [--assert-zero-drops]
"""
import argparse
import json
import os
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def force_device_count(n: int) -> None:
    """Append the host-platform fan-out to XLA_FLAGS (preserving any
    flags already set) — must run before the first jax import."""
    flag = f"--xla_force_host_platform_device_count={n}"
    existing = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in existing:
        os.environ["XLA_FLAGS"] = f"{existing} {flag}".strip()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--buckets", default="8,16")
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--page-size", type=int, default=4)
    ap.add_argument("--mtbf", type=float, default=400.0,
                    help="burst-campaign MTBF seconds (seconds-per-step "
                         "100: expect a kill every ~4 server steps)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--assert-zero-drops", action="store_true",
                    help="CI gate: fail unless the burst run completes "
                         "every request with zero recompiles and "
                         "outputs bit-identical to the healthy run")
    ap.add_argument("--out", default=str(ROOT / "BENCH_serving.json"))
    args = ap.parse_args()

    force_device_count(args.devices)

    import jax
    import numpy as np

    from repro.configs import smoke_config
    from repro.data import RequestStream
    from repro.launch.serve import serve_and_measure
    from repro.models import build_model
    from repro.obs.metrics import latency_stats   # p50/p99/p99.9, exact
    from repro.serve import ReplicaServer, pool_pages_for
    from repro.des.params import DESParams
    from repro.scenarios.topology import ClusterTopology
    from repro.train import ScenarioInjector

    cfg = smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    buckets = tuple(int(b) for b in args.buckets.split(","))
    kwargs = dict(
        n_slots=args.slots, page_size=args.page_size,
        max_new=args.max_new, buckets=buckets,
        n_pages=pool_pages_for(args.slots, max(buckets) + args.max_new,
                               args.page_size))
    stream = RequestStream(cfg, buckets=buckets, max_new=args.max_new,
                           seed=args.seed)

    def measure(injector):
        srv = ReplicaServer(model, params, n_replicas=args.replicas,
                            injector=injector, engine_kwargs=kwargs)
        srv.warmup()
        frozen = srv.recompiles
        done, wall = serve_and_measure(srv, stream.requests(args.requests))
        stats = latency_stats(done)
        return srv, done, {
            **stats,
            "wall_s": round(wall, 3),
            "tokens_per_s": round(stats["tokens"] / wall, 2),
            "completed_requests": len(done),
            "recompiles_after_warmup": srv.recompiles - frozen,
        }

    # throwaway warm pass: AOT warmup compiles but does not execute, and
    # first executions carry one-time dispatch/allocation costs that
    # would land entirely on whichever run goes first (measured 3x skew)
    measure(None)

    srv_h, done_h, healthy = measure(None)

    topo = ClusterTopology(n_groups=args.replicas, hosts_per_group=1,
                           hosts_per_rack=1)     # one replica per rack
    injector = ScenarioInjector(
        {"kind": "correlated", "scope": "rack", "burst_prob": 1.0,
         "mtbf": args.mtbf},
        topo, n_groups=args.replicas, seconds_per_step=100.0,
        params=DESParams(n=args.replicas, mtbf=args.mtbf), seed=args.seed + 3)
    srv_b, done_b, degraded = measure(injector)

    want = {d.req_id: d.tokens for d in done_h}
    got = {d.req_id: d.tokens for d in done_b}
    identical = (want.keys() == got.keys() and
                 all(np.array_equal(want[k], got[k]) for k in want))

    rec = {
        "bench": "serving",
        "arch": args.arch,
        "mesh": f"emulated-{args.devices}",
        "replicas": args.replicas,
        "slots_per_replica": args.slots,
        "requests": args.requests,
        "buckets": list(buckets),
        "max_new": args.max_new,
        "healthy": healthy,
        "degraded": degraded,
        "degraded_events": [(e.step, e.kind, e.victims, e.requeued)
                            for e in srv_b.events],
        "replicas_lost": args.replicas - int(srv_b.spare.alive.sum()),
        "outputs_identical": identical,
        "dropped_requests": args.requests - degraded["completed_requests"],
        "throughput_retention_pct": round(
            100.0 * degraded["tokens_per_s"] / healthy["tokens_per_s"], 1),
        "executables": [list(k) for k in srv_b.exec_cache.keys],
    }

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    history = json.loads(out.read_text()) if out.exists() else []
    history.append(rec)
    out.write_text(json.dumps(history, indent=1))
    print(json.dumps(rec, indent=1))

    if args.assert_zero_drops:
        assert rec["degraded_events"], \
            "burst campaign produced no failures — gate is vacuous"
        assert rec["dropped_requests"] == 0, rec
        assert rec["healthy"]["recompiles_after_warmup"] == 0, rec
        assert rec["degraded"]["recompiles_after_warmup"] == 0, rec
        assert rec["outputs_identical"], \
            "degraded outputs differ from the healthy run"


if __name__ == "__main__":
    main()
